#!/usr/bin/env python
"""Docs lane (``tools/ci.sh --docs``): keep the documentation honest.

Two checks:

1. **Link check** — every relative markdown link in README.md / DESIGN.md /
   CHANGES.md must point at a file that exists (http(s)/mailto links are not
   fetched; ``#fragment`` suffixes are stripped).
2. **Command check** — every ```` ```bash ```` fenced block in README.md is
   executed from the repo root (``bash -euo pipefail``, ``PYTHONPATH=src``).
   Display-only snippets (install lines, long sweeps) use ```` ```text ````
   or ```` ```python ```` fences and are skipped — the convention that makes
   "every bash command in the README runs green" checkable.

Exit status is non-zero on any broken link or failing command.
"""
from __future__ import annotations

import os
import re
import subprocess
import sys
import time
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
LINK_FILES = ("README.md", "DESIGN.md", "CHANGES.md")
RUN_FILE = "README.md"

LINK_RE = re.compile(r"\[[^\]]+\]\(([^)\s]+)\)")
FENCE_RE = re.compile(r"^```(\w*)\s*$")


def check_links() -> list[str]:
    errors = []
    for name in LINK_FILES:
        path = ROOT / name
        if not path.exists():
            errors.append(f"{name}: file missing")
            continue
        for i, line in enumerate(path.read_text().splitlines(), 1):
            for target in LINK_RE.findall(line):
                if target.startswith(("http://", "https://", "mailto:")):
                    continue
                rel = target.split("#", 1)[0]
                if not rel:                    # pure in-page anchor
                    continue
                if not (path.parent / rel).exists():
                    errors.append(f"{name}:{i}: broken link -> {target}")
    return errors


def bash_blocks(text: str) -> list[tuple[int, str]]:
    """(first_line_no, script) for each ```bash fenced block."""
    blocks, cur, lang, start = [], None, None, 0
    for i, line in enumerate(text.splitlines(), 1):
        m = FENCE_RE.match(line)
        if m and cur is None:
            lang, cur, start = m.group(1), [], i + 1
        elif line.strip() == "```" and cur is not None:
            if lang == "bash":
                blocks.append((start, "\n".join(cur)))
            cur, lang = None, None
        elif cur is not None:
            cur.append(line)
    return blocks


def run_blocks() -> list[str]:
    errors = []
    env = dict(os.environ)
    env["PYTHONPATH"] = f"src{os.pathsep}" + env.get("PYTHONPATH", "")
    blocks = bash_blocks((ROOT / RUN_FILE).read_text())
    print(f"[docs] {RUN_FILE}: {len(blocks)} bash block(s) to execute")
    for lineno, script in blocks:
        head = script.strip().splitlines()[0] if script.strip() else "<empty>"
        print(f"[docs] {RUN_FILE}:{lineno}: $ {head}")
        t0 = time.time()
        proc = subprocess.run(["bash", "-euo", "pipefail", "-c", script],
                              cwd=ROOT, env=env)
        status = "ok" if proc.returncode == 0 else f"FAILED ({proc.returncode})"
        print(f"[docs]   -> {status} in {time.time()-t0:.1f}s")
        if proc.returncode != 0:
            errors.append(f"{RUN_FILE}:{lineno}: block failed "
                          f"(exit {proc.returncode}): {head}")
    return errors


def main() -> int:
    errors = check_links()
    for e in errors:
        print(f"[docs] {e}", file=sys.stderr)
    if "--links-only" not in sys.argv:
        errors += run_blocks()
    if errors:
        print(f"[docs] {len(errors)} problem(s)", file=sys.stderr)
        return 1
    print("[docs] all links resolve and all README bash blocks ran green")
    return 0


if __name__ == "__main__":
    sys.exit(main())
