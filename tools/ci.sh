#!/usr/bin/env bash
# Tier-1 verify: the exact command the roadmap pins. Extra args pass through
# (e.g. `tools/ci.sh -m "not slow"` for the fast lane).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -x -q "$@"
