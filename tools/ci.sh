#!/usr/bin/env bash
# Tier-1 verify: the exact command the roadmap pins. Extra args pass through
# (e.g. `tools/ci.sh -m "not slow"` for the fast lane).
#
# Extra lanes (used by .github/workflows/ci.yml):
#   tools/ci.sh --halo         halo-exchange parity tests with 4 forced host
#                              devices (runs the shard_map compact/dense parity
#                              checks in-process instead of skipping them)
#   tools/ci.sh --bench-smoke  fast benchmark regression checks: bench_halo
#                              fails if the compact layout's wire-byte
#                              reduction regresses past 60%; bench_overlap
#                              fails if the overlap schedule stops hiding comm
#                              (modeled step must beat compute + comm) or
#                              loses bit-exactness vs blocking; bench_serve fails
#                              if the quantized delta refresh ships more than
#                              10% of the full 32-bit sweep bytes; bench_chaos
#                              fails if the armed fault path's epoch overhead
#                              regresses; bench_store fails if store-backed
#                              reads diverge, the cache hit rate drops below
#                              0.9, or open-loop p99 breaks the SLO (all write
#                              untracked *.smoke.json; only full runs update
#                              the tracked BENCH_*.json records)
#   tools/ci.sh --overlap      overlap-schedule parity suite with 4 forced
#                              host devices (runs the shard_map blocking-vs-
#                              overlap bit-exactness check in-process instead
#                              of skipping it; the hypothesis property tests
#                              ride along when the dev extra is installed)
#   tools/ci.sh --policy       CommPolicy suite with 4 forced host devices
#                              (runs the shard_map Uniform-parity check
#                              in-process instead of skipping it)
#   tools/ci.sh --serve        repro.serve suite with 4 forced host devices
#                              (runs the shard_map serving-parity + delta
#                              refresh checks in-process instead of skipping)
#   tools/ci.sh --store        repro.store suite (sharded embedding store,
#                              hot-node cache, mutation stream, multi-replica
#                              serving) with 4 forced host devices, then the
#                              bench_store smoke gate (bit-exact store-backed
#                              reads, >= 0.9 cache hit rate on the skewed
#                              workload, open-loop p99 within SLO under the
#                              streaming feed)
#   tools/ci.sh --chaos        fault-tolerance suite with 4 forced host
#                              devices (seeded injection, staleness recovery,
#                              kill-and-resume), then the chaos launcher's
#                              own self-check (repro.launch.chaos --ci)
#   tools/ci.sh --obs          observability lane: repro.obs suite (span
#                              tracer, metrics registry, exporters, CLI,
#                              instrumented layers), then a traced smoke
#                              scenario slice (--obs writes Perfetto trace +
#                              metrics JSON under artifacts/obs/smoke/)
#                              rendered by `python -m repro.obs summarize`
#                              (exit-code gated), then the bench_obs smoke
#                              gate (disabled-tracer overhead <= 1%)
#   tools/ci.sh --docs         documentation lane: markdown link check over
#                              README/DESIGN/CHANGES + execution of every
#                              README ```bash block (quickstart, scenario
#                              smoke, fast verify) via tools/check_docs.py.
#                              `--docs --links-only` skips the executions.
#   tools/ci.sh --analysis     static-analysis gate: `python -m repro.analysis`
#                              (trace-discipline AST lint + jaxpr contract
#                              suite, baseline-gated, JSON report to
#                              artifacts/analysis/), then ruff + mypy when
#                              installed (CI installs them; locally they are
#                              skipped with a notice, never silently passed
#                              as success of the repro.analysis gate).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
case "${1:-}" in
  --policy)
    shift
    XLA_FLAGS="--xla_force_host_platform_device_count=4${XLA_FLAGS:+ $XLA_FLAGS}" \
      exec python -m pytest -x -q tests/test_policy.py -m "not slow" "$@"
    ;;
  --halo)
    shift
    XLA_FLAGS="--xla_force_host_platform_device_count=4${XLA_FLAGS:+ $XLA_FLAGS}" \
      exec python -m pytest -x -q tests/test_halo_compact.py \
      tests/test_kernels.py -m "not slow" "$@"
    ;;
  --serve)
    shift
    XLA_FLAGS="--xla_force_host_platform_device_count=4${XLA_FLAGS:+ $XLA_FLAGS}" \
      exec python -m pytest -x -q tests/test_serve.py -m "not slow" "$@"
    ;;
  --overlap)
    shift
    XLA_FLAGS="--xla_force_host_platform_device_count=4${XLA_FLAGS:+ $XLA_FLAGS}" \
      exec python -m pytest -x -q tests/test_overlap.py \
      tests/test_overlap_properties.py -m "not slow" "$@"
    ;;
  --store)
    shift
    XLA_FLAGS="--xla_force_host_platform_device_count=4${XLA_FLAGS:+ $XLA_FLAGS}" \
      python -m pytest -x -q tests/test_store.py -m "not slow" "$@"
    exec python -m benchmarks.bench_store --smoke
    ;;
  --chaos)
    shift
    XLA_FLAGS="--xla_force_host_platform_device_count=4${XLA_FLAGS:+ $XLA_FLAGS}" \
      python -m pytest -x -q tests/test_faults.py "$@"
    exec python -m repro.launch.chaos --ci
    ;;
  --bench-smoke)
    shift
    python -m benchmarks.bench_halo --smoke "$@"
    python -m benchmarks.bench_overlap --smoke "$@"
    python -m benchmarks.bench_serve --smoke "$@"
    python -m benchmarks.bench_chaos --smoke "$@"
    exec python -m benchmarks.bench_store --smoke "$@"
    ;;
  --obs)
    shift
    XLA_FLAGS="--xla_force_host_platform_device_count=4${XLA_FLAGS:+ $XLA_FLAGS}" \
      python -m pytest -x -q tests/test_obs.py -m "not slow" "$@"
    python -m repro.launch.train --scenario smoke --only gcn__yelp_like --obs
    python -m repro.obs summarize artifacts/obs/smoke
    exec python -m benchmarks.bench_obs --smoke
    ;;
  --docs)
    shift
    exec python tools/check_docs.py "$@"
    ;;
  --analysis)
    shift
    python -m repro.analysis --json "$@"
    if command -v ruff >/dev/null 2>&1; then
      ruff check src tests benchmarks tools
    else
      echo "ruff not installed - skipping (CI installs it; pip install ruff)"
    fi
    if command -v mypy >/dev/null 2>&1; then
      mypy --config-file pyproject.toml
    else
      echo "mypy not installed - skipping (CI installs it; pip install mypy)"
    fi
    exit 0
    ;;
esac
exec python -m pytest -x -q "$@"
