#!/usr/bin/env bash
# Tier-1 verify: the exact command the roadmap pins. Extra args pass through
# (e.g. `tools/ci.sh -m "not slow"` for the fast lane).
#
# Extra lanes (used by .github/workflows/ci.yml):
#   tools/ci.sh --halo         halo-exchange parity tests with 4 forced host
#                              devices (runs the shard_map compact/dense parity
#                              checks in-process instead of skipping them)
#   tools/ci.sh --bench-smoke  fast bench_halo regression check: fails if the
#                              compact layout's wire-byte reduction regresses
#                              past 60% (writes the untracked
#                              BENCH_halo.smoke.json; only full runs of
#                              `python -m benchmarks.bench_halo` update the
#                              tracked BENCH_halo.json)
#   tools/ci.sh --policy       CommPolicy suite with 4 forced host devices
#                              (runs the shard_map Uniform-parity check
#                              in-process instead of skipping it)
#   tools/ci.sh --docs         documentation lane: markdown link check over
#                              README/DESIGN/CHANGES + execution of every
#                              README ```bash block (quickstart, scenario
#                              smoke, fast verify) via tools/check_docs.py.
#                              `--docs --links-only` skips the executions.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
case "${1:-}" in
  --policy)
    shift
    XLA_FLAGS="--xla_force_host_platform_device_count=4${XLA_FLAGS:+ $XLA_FLAGS}" \
      exec python -m pytest -x -q tests/test_policy.py -m "not slow" "$@"
    ;;
  --halo)
    shift
    XLA_FLAGS="--xla_force_host_platform_device_count=4${XLA_FLAGS:+ $XLA_FLAGS}" \
      exec python -m pytest -x -q tests/test_halo_compact.py \
      tests/test_kernels.py -m "not slow" "$@"
    ;;
  --bench-smoke)
    shift
    exec python -m benchmarks.bench_halo --smoke "$@"
    ;;
  --docs)
    shift
    exec python tools/check_docs.py "$@"
    ;;
esac
exec python -m pytest -x -q "$@"
